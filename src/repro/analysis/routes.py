"""Engine-route enumeration + abstract lowering for the auditor.

One definition of "every program the engine can run" that every pass
shares: local / batch / find / distributed, crossed with the jnp and
(interpreted) Pallas intersection backends, with per-vertex attribution
on and off, and — on the distributed route — both hedge exchange modes
and a device-count axis.  Each :class:`RouteSpec` lowers its jit
programs to closed jaxprs from ``ShapeDtypeStruct``s only: nothing in
this module executes device code, so the auditor can reason about
Graph500-scale shapes on a laptop.

The local route contributes TWO programs (its exact pipeline is a plan
jit plus a run jit separated by one host sync); the batch/serving route
is the fused single-jit hot path; find is the per-bucket probe block;
distributed is the full shard_map body, lowered exactly like PR 4's
dry-run path (``comm_instrument.measure_tc_comm``); stream is the
level-free exact-planned delta probe the streaming subsystem issues per
mutation batch (``repro.stream.delta.probe_sum`` — the one device
program of the stream route; its refresh reuses the local route's
programs verbatim).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.intersect import IntersectPlan, plan_buckets_bounded
from repro.graph.csr import (
    META_ROW_QUANT,
    META_WIDTHS,
    BatchDegreeMeta,
    Graph,
)

#: intersection backends every route is audited under.  Pallas runs in
#: interpret mode — the audit must work on CPU CI runners, and the
#: jaxpr-level structure is what the passes consume.
BACKENDS = (("jnp", True), ("pallas", True))

#: distributed hedge exchange modes.
HEDGE_MODES = ("allgather", "ring")


def _next_pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1).bit_length())


def _ceil_to(x: int, mult: int) -> int:
    return max(mult, -(-int(x) // mult) * mult)


def synthetic_meta(n_budget: int, slot_budget: int,
                   *, d_pad: Optional[int] = None) -> BatchDegreeMeta:
    """A valid ``BatchDegreeMeta`` for a worst-case batch at this budget
    — every bound at its ceiling, exceedance decaying across the width
    grid so bounded plans lay out realistic multi-bucket shapes.  This
    is what "audit a budget cell without a graph" means: the meta IS
    the cell's upper bound, no data required."""
    d = int(d_pad) if d_pad is not None else min(
        _next_pow2(max(2, n_budget // 8)), 1024
    )
    h_rows = _ceil_to(max(1, slot_budget // 2), META_ROW_QUANT)
    exceed = []
    for i, w in enumerate(META_WIDTHS):
        c = h_rows >> (i + 1) if w < d else 0
        exceed.append((w, _ceil_to(c, META_ROW_QUANT) if c else 0))
    return BatchDegreeMeta(d_pad=d, h_rows=h_rows, exceed=tuple(exceed))


def bounded_plan(meta: BatchDegreeMeta, *, backend: str = "jnp",
                 interpret: bool = True,
                 query_chunk: Optional[int] = None) -> IntersectPlan:
    """The serving-path bounded plan for a synthetic meta — host-only."""
    return plan_buckets_bounded(
        meta.h_rows, d_pad=meta.d_pad, exceed=meta.exceed,
        backend=backend, interpret=interpret, query_chunk=query_chunk,
        row_mult=META_ROW_QUANT, sort_queries=False,
    )


def abstract_lane_view(n_budget: int, slot_budget: int,
                       batch: int) -> Graph:
    """``GraphBatch.lane_view()`` as ShapeDtypeStructs — lane-axis
    int32 arrays at the budget, the exact avals every serving flush
    traces with (the device program is x32; the bounds pass supplies
    the TRUE value ranges separately)."""
    s = jax.ShapeDtypeStruct
    i32 = jnp.int32
    return Graph(
        src=s((batch, slot_budget), i32),
        dst=s((batch, slot_budget), i32),
        row_offsets=s((batch, n_budget + 2), i32),
        deg=s((batch, n_budget), i32),
        n_edges_dir=s((batch,), i32),
        n_nodes=int(n_budget),
    )


def abstract_single_graph(n_nodes: int, num_slots: int) -> Graph:
    """Single-graph avals at the current x32 device dtypes."""
    s = jax.ShapeDtypeStruct
    i32 = jnp.int32
    return Graph(
        src=s((num_slots,), i32),
        dst=s((num_slots,), i32),
        row_offsets=s((n_nodes + 2,), i32),
        deg=s((n_nodes,), i32),
        n_edges_dir=s((), i32),
        n_nodes=int(n_nodes),
    )


@dataclasses.dataclass(frozen=True)
class RouteSpec:
    """One audited engine configuration.

    ``name`` is the stable finding-site prefix; ``programs()`` lowers
    the configuration's jit program(s) to ``(label, closed_jaxpr)``
    pairs without executing anything."""

    name: str
    route: str                # local | batch | find | distributed | stream
    backend: str
    interpret: bool
    per_vertex: bool
    mode: Optional[str] = None     # distributed hedge mode
    p: int = 1                     # distributed device count
    n_budget: int = 64
    slot_budget: int = 256
    batch: int = 2

    def programs(self) -> list[tuple[str, object]]:
        if self.route == "distributed":
            fn, args = self.shard_program()
            return [(f"{self.name}/shard", jax.make_jaxpr(fn)(*args))]
        if self.route == "batch":
            return [(f"{self.name}/fused", self._fused_jaxpr())]
        if self.route == "local":
            return self._local_jaxprs()
        if self.route == "find":
            return [(f"{self.name}/find_block", self._find_jaxpr())]
        if self.route == "stream":
            return [(f"{self.name}/delta_probe", self._stream_jaxpr())]
        raise ValueError(f"unknown route {self.route!r}")

    # ---------------------------------------------------- batch route
    def _plan(self) -> IntersectPlan:
        meta = synthetic_meta(self.n_budget, self.slot_budget)
        return bounded_plan(meta, backend=self.backend,
                            interpret=self.interpret)

    def _fused_jaxpr(self):
        from repro.core import sequential as seq

        gview = abstract_lane_view(self.n_budget, self.slot_budget,
                                   self.batch)
        fn = functools.partial(
            seq._tc_batch_fused, plan=self._plan(), root=0,
            per_vertex=self.per_vertex,
        )
        return jax.make_jaxpr(fn)(gview)

    # ---------------------------------------------------- local route
    def _local_jaxprs(self):
        from repro.core import sequential as seq

        gview = abstract_lane_view(self.n_budget, self.slot_budget,
                                   self.batch)
        plan_fn = functools.partial(seq._plan_batch, root=0)
        plan_jaxpr = jax.make_jaxpr(plan_fn)(gview)
        # stage 2's query avals come from stage 1's output shapes —
        # eval_shape is the no-execution bridge across the host sync
        level, qu, qw, *_ = jax.eval_shape(plan_fn, gview)
        run_fn = functools.partial(
            seq._run_batch, plan=self._plan(), per_vertex=self.per_vertex
        )
        run_jaxpr = jax.make_jaxpr(run_fn)(gview, qu, qw, level)
        return [(f"{self.name}/plan", plan_jaxpr),
                (f"{self.name}/run", run_jaxpr)]

    # ----------------------------------------------------- find route
    def _find_jaxpr(self):
        from repro.core import sequential as seq

        g = abstract_single_graph(self.n_budget, self.slot_budget)
        plan = self._plan()
        b = plan.buckets[0]
        s = jax.ShapeDtypeStruct
        qrow = s((b.rows,), jnp.int32)
        level = s((self.n_budget,), jnp.int32)
        fn = functools.partial(
            seq._find_block, d_cand=b.d_cand, d_targ=b.d_targ,
            backend=self.backend, interpret=self.interpret,
            max_triangles=64,
        )
        return jax.make_jaxpr(fn)(g, qrow, qrow, level)

    # ---------------------------------------------------- stream route
    def _stream_jaxpr(self):
        from repro.core.intersect import (
            DEFAULT_BUCKET_WIDTHS,
            CsrAdjacency,
            plan_buckets,
            run_plan,
        )

        # a synthetic net-batch degree profile spanning the default
        # width grid — the exact host plan the session prices per batch
        # (stream.delta.probe_sum).  The device program is ONE
        # level-free run_plan over the delta query block; the pinned
        # profile keeps the lowered structure (and the baseline's site
        # keys) identical on any host.
        ds = np.array([1, 2, 2, 4, 4, 8, 8, 16], dtype=np.int64)
        plan = plan_buckets(
            ds, 2 * ds, bucket_widths=DEFAULT_BUCKET_WIDTHS,
            backend=self.backend, interpret=self.interpret,
        )
        g = abstract_single_graph(self.n_budget, self.slot_budget)
        q = jax.ShapeDtypeStruct((int(ds.size),), jnp.int32)

        def fn(flat, row_offsets, deg, qu, qw):
            adj = CsrAdjacency(flat=flat, row_offsets=row_offsets,
                               deg=deg, n_nodes=self.n_budget)
            return run_plan(adj, qu, qw, plan, level=None,
                            per_vertex=self.per_vertex)

        return jax.make_jaxpr(fn)(g.dst, g.row_offsets, g.deg, q, q)

    # ---------------------------------------------- distributed route
    def shard_program(self) -> tuple[Callable, tuple]:
        """The shard_map program + its ShapeDtypeStruct args — shared
        by the jaxpr passes (``make_jaxpr``) and the collective pass's
        StableHLO cross-check (``jax.jit(fn).lower(*args)``).  Needs
        ``p`` local devices (CI forces 8 host devices via XLA_FLAGS)."""
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.compat import shard_map
        from repro.core.parallel_tc import (
            build_tc_shard_fn,
            result_out_specs,
        )

        devs = jax.devices()
        if len(devs) < self.p:
            raise ValueError(
                f"route {self.name}: need {self.p} devices, found "
                f"{len(devs)} (set --xla_force_host_platform_device_count)"
            )
        mesh = Mesh(np.array(devs[: self.p]).reshape(self.p), ("p",))
        m2 = self.slot_budget
        fn, cap_edges = build_tc_shard_fn(
            n=self.n_budget, m2=m2, p=self.p, mode=self.mode or "allgather",
            intersect_backend=self.backend, interpret=self.interpret,
            per_vertex=self.per_vertex,
        )
        shard = shard_map(
            fn, mesh=mesh, in_specs=(P("p"), P("p")),
            out_specs=result_out_specs("p", per_vertex=self.per_vertex),
        )
        spec = jax.ShapeDtypeStruct((self.p * cap_edges,), jnp.int32)
        return shard, (spec, spec)


def enumerate_route_specs(
    *,
    n_budget: int = 64,
    slot_budget: int = 256,
    batch: int = 2,
    p_values: tuple[int, ...] = (1,),
) -> list[RouteSpec]:
    """The full audited route space: local/batch/find/stream × backend
    × per_vertex, plus distributed × backend × per_vertex × mode × p.
    ``p_values`` beyond the local device count are skipped by callers
    that execute lowering (the CLI forces 8 host devices first).

    Backends are pinned (never ``"auto"``) so the enumeration — and
    therefore every baseline site key — is identical on any host."""
    shape = dict(n_budget=n_budget, slot_budget=slot_budget, batch=batch)
    specs: list[RouteSpec] = []
    for backend, interpret in BACKENDS:
        for pv in (False, True):
            tag = f"{backend}{'/pv' if pv else ''}"
            specs.append(RouteSpec(
                name=f"batch/{tag}", route="batch", backend=backend,
                interpret=interpret, per_vertex=pv, **shape,
            ))
            specs.append(RouteSpec(
                name=f"local/{tag}", route="local", backend=backend,
                interpret=interpret, per_vertex=pv, **shape,
            ))
            if not pv:  # finding has no per-vertex variant
                specs.append(RouteSpec(
                    name=f"find/{tag}", route="find", backend=backend,
                    interpret=interpret, per_vertex=pv, **shape,
                ))
            specs.append(RouteSpec(
                name=f"stream/{tag}", route="stream", backend=backend,
                interpret=interpret, per_vertex=pv, **shape,
            ))
            for mode in HEDGE_MODES:
                for p in p_values:
                    specs.append(RouteSpec(
                        name=f"distributed/{tag}/{mode}/p{p}",
                        route="distributed", backend=backend,
                        interpret=interpret, per_vertex=pv, mode=mode,
                        p=p, **shape,
                    ))
    return specs
