"""Quickstart: cover-edge triangle counting (the paper's Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import networkx as nx
import numpy as np

from repro.core.sequential import find_triangles, triangle_count
from repro.graph import generators as gen
from repro.graph.csr import from_edges, max_degree


def main():
    for name, (edges, n) in {
        "karate": gen.karate(),
        "dolphins-like (62 vertices)": gen.dolphins_like(),
        "Graph500 RMAT scale 10": gen.rmat(10, 16, seed=0),
    }.items():
        g = from_edges(edges, n)
        res = triangle_count(g, d_max=max_degree(g))
        G = nx.Graph()
        G.add_nodes_from(range(n))
        G.add_edges_from(np.asarray(edges))
        G.remove_edges_from(nx.selfloop_edges(G))
        want = sum(nx.triangles(G).values()) // 3
        print(f"{name}:")
        print(f"  triangles = {int(res.triangles)} (networkx: {want})")
        print(f"  horizontal-edge fraction k = {float(res.k):.3f}")
        print(f"  c1 (apex off-level) = {int(res.c1)}, "
              f"c2 (all-same-level, triple-counted) = {int(res.c2)}")
    # triangle FINDING on karate
    edges, n = gen.karate()
    g = from_edges(edges, n)
    tri, cnt = find_triangles(g, d_max=max_degree(g), max_triangles=64)
    print(f"\nfirst 5 of {int(cnt)} karate triangles: "
          f"{np.asarray(tri)[:5].tolist()}")


if __name__ == "__main__":
    main()
