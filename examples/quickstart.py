"""Quickstart: cover-edge triangle counting through the one front door
(`repro.api.TriangleEngine` — Algorithm 1 under the hood).

    PYTHONPATH=src python examples/quickstart.py
"""
import networkx as nx
import numpy as np

from repro.api import TriangleEngine
from repro.graph import generators as gen
from repro.graph.csr import from_edges


def main():
    engine = TriangleEngine()
    for name, (edges, n) in {
        "karate": gen.karate(),
        "dolphins-like (62 vertices)": gen.dolphins_like(),
        "Graph500 RMAT scale 10": gen.rmat(10, 16, seed=0),
    }.items():
        rep = engine.count((edges, n))  # Graph objects work too
        G = nx.Graph()
        G.add_nodes_from(range(n))
        G.add_edges_from(np.asarray(edges))
        G.remove_edges_from(nx.selfloop_edges(G))
        want = sum(nx.triangles(G).values()) // 3
        print(f"{name}:")
        print(f"  triangles = {rep.triangles} (networkx: {want})")
        print(f"  horizontal-edge fraction k = {rep.k:.3f}")
        print(f"  c1 (apex off-level) = {rep.c1}, "
              f"c2 (all-same-level, triple-counted) = {rep.c2}")
        print(f"  provenance: route={rep.route} backend={rep.backend} "
              f"plan={rep.plan_id}")
    # triangle FINDING on karate — same engine, same options
    edges, n = gen.karate()
    g = from_edges(edges, n)
    tri, cnt = engine.find(g, max_triangles=64)
    print(f"\nfirst 5 of {int(cnt)} karate triangles: "
          f"{np.asarray(tri)[:5].tolist()}")
    # BATCHED counting: many small query graphs in one call (one shared
    # static budget, one cached plan, one vmapped program — DESIGN.md §4;
    # the engine owns the budget grid and the plan cache)
    batch = [gen.karate(), gen.complete(9),
             gen.erdos_renyi(60, 0.1, seed=1)]
    reports = engine.count_batch(batch)
    print(f"\ncount_batch of {len(batch)} graphs "
          f"(plan {reports[0].plan_id}):")
    for i, rep in enumerate(reports):
        print(f"  graph {i}: n={batch[i][1]} "
              f"triangles={rep.triangles} k={rep.k:.3f}")
    print(f"plan cache: {engine.plan_cache_stats()}")


if __name__ == "__main__":
    main()
