"""Quickstart: cover-edge triangle counting (the paper's Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import networkx as nx
import numpy as np

from repro.core.sequential import (
    find_triangles,
    triangle_count,
    triangle_count_batch,
)
from repro.graph import generators as gen
from repro.graph.csr import from_edges, from_edges_batch, max_degree


def main():
    for name, (edges, n) in {
        "karate": gen.karate(),
        "dolphins-like (62 vertices)": gen.dolphins_like(),
        "Graph500 RMAT scale 10": gen.rmat(10, 16, seed=0),
    }.items():
        g = from_edges(edges, n)
        res = triangle_count(g, d_max=max_degree(g))
        G = nx.Graph()
        G.add_nodes_from(range(n))
        G.add_edges_from(np.asarray(edges))
        G.remove_edges_from(nx.selfloop_edges(G))
        want = sum(nx.triangles(G).values()) // 3
        print(f"{name}:")
        print(f"  triangles = {int(res.triangles)} (networkx: {want})")
        print(f"  horizontal-edge fraction k = {float(res.k):.3f}")
        print(f"  c1 (apex off-level) = {int(res.c1)}, "
              f"c2 (all-same-level, triple-counted) = {int(res.c2)}")
    # triangle FINDING on karate
    edges, n = gen.karate()
    g = from_edges(edges, n)
    tri, cnt = find_triangles(g, d_max=max_degree(g), max_triangles=64)
    print(f"\nfirst 5 of {int(cnt)} karate triangles: "
          f"{np.asarray(tri)[:5].tolist()}")
    # BATCHED counting: many small query graphs in one call (one shared
    # static budget, one plan, one vmapped program — see DESIGN.md §4)
    batch = [gen.karate(), gen.complete(9),
             gen.erdos_renyi(60, 0.1, seed=1)]
    gb = from_edges_batch(batch)
    res = triangle_count_batch(gb)
    print(f"\nGraphBatch of {gb.batch_size} on budget {gb.budget}:")
    for i in range(gb.batch_size):
        print(f"  lane {i}: n={int(gb.n_nodes[i])} "
              f"triangles={int(res.triangles[i])} k={float(res.k[i]):.3f}")


if __name__ == "__main__":
    main()
