"""Distributed cover-edge triangle counting (the paper's Algorithm 2) on
8 simulated devices, vs the wedge-query baseline it replaces — driven
through the ``TriangleEngine`` front door's distributed route.

Algorithm 2's per-device probing runs through the shared intersection
engine: ``plan_hedge_rounds`` lays out static degree buckets on the host
(from the graph's degree histogram, valid for any BFS) and every round
executes that plan against the transposed pair lists — the same
plan/run split the local route uses (DESIGN.md §3).

    PYTHONPATH=src python examples/distributed_tc.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.api import TCOptions, TriangleEngine  # noqa: E402
from repro.core import comm_model as cm  # noqa: E402
from repro.core.parallel_tc import plan_hedge_rounds  # noqa: E402
from repro.core.wedge_baseline import (  # noqa: E402
    parallel_wedge_triangle_count, wedge_count,
)
from repro.graph import generators as gen  # noqa: E402
from repro.graph.csr import from_edges  # noqa: E402


def main():
    p = 8
    mesh = Mesh(np.array(jax.devices()[:p]).reshape(p), ("p",))
    edges, n = gen.rmat(11, 16, seed=0)
    g = from_edges(edges, n)
    m = int(g.n_edges_dir) // 2

    # hedge_chunk is both the fori-loop probe slice and the bucket-row
    # granularity — without it the whole per-round buffer is one bucket
    chunk = 512
    engine = TriangleEngine(
        TCOptions(mode="ring", hedge_chunk=chunk, backend="auto"),
        mesh=mesh,
    )
    plan = plan_hedge_rounds(g, p, mode="ring", hedge_chunk=chunk)
    print(f"RMAT scale 11: n={n} m={m}")
    print("planned horizontal rounds (one engine bucket per line):")
    for b in plan.buckets:
        print(f"  rows={b.rows:>6}  candidate width={b.d_cand:>4}  "
              f"target width={b.d_targ}")

    rep = engine.count(g, route="distributed")
    wres = parallel_wedge_triangle_count(g, mesh)
    print(f"cover-edge (ring): T={rep.triangles}  k={rep.k:.3f}"
          f"  per-device={rep.per_device.tolist()}")
    print(f"  measured horizontal fraction k = {rep.k:.3f} "
          f"({rep.num_horizontal} of {m} undirected edges)")
    print(f"  overflow flags: transpose={rep.overflow.transpose} "
          f"hedge={rep.overflow.hedge} (static capacities held)")
    print(f"  unified report: route={rep.route} plan={rep.plan_id} "
          f"c1={rep.c1} c2={rep.c2} (Alg 2 has no apex-level split)")
    print(f"wedge baseline:    T={int(wres.triangles)}  "
          f"wedges routed={int(wres.wedges_routed)}")

    new = cm.cover_edge_comm(n, m, rep.k, p).total_bytes
    old = cm.wedge_comm_bits(float(wedge_count(g)), n) / 8
    print(f"\nmodelled comm: wedge={cm.fmt_bytes(old)} "
          f"cover-edge={cm.fmt_bytes(new)} -> {old/new:.1f}x reduction")

    # the measured loop (DESIGN.md §5): every distributed report carries
    # its CommTally, and the instrument's per-collective extraction must
    # match it
    from repro.core import comm_instrument as ci

    tally = rep.comm.phase_bytes()
    sweeps = int(jax.device_get(rep.comm.bfs_sweeps))
    repm = ci.comm_report(n, int(g.n_edges_dir), p, sweeps=sweeps,
                          mode="ring", hedge_chunk=chunk)
    print(f"\nmeasured wire bytes (ring, p={p}, {sweeps} BFS sweeps):")
    for ph, row in repm["phases"].items():
        agree = "==" if row["measured"] == tally[ph] else "!="
        print(f"  {ph:>9}: measured={row['measured']:>10} {agree} "
              f"tally={tally[ph]:>10}  modeled={row['modeled']:.0f}")
    assert all(r["measured"] == tally[ph]
               for ph, r in repm["phases"].items())


if __name__ == "__main__":
    main()
