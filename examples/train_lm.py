"""End-to-end LM training with checkpoint/restart (smoke config by default;
pass --full to train the real smollm-135m — sized for a TPU slice, slow on
this CPU container).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

import jax

from repro.configs.registry import arch_module
from repro.launch import steps as steps_mod
from repro.train.data import LMStream
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    mod = arch_module("smollm-135m")
    cfg = mod.CONFIG if args.full else mod.SMOKE
    params = steps_mod.init_for("smollm-135m", cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params")

    trainer = Trainer(
        steps_mod.lm_loss(cfg), params,
        OptConfig(lr=1e-3, warmup=20, total_steps=args.steps),
        ckpt_dir=args.ckpt_dir, cfg=cfg, ckpt_every=50,
    )
    if trainer.maybe_restore():
        print(f"resumed from step {trainer.step_num}")
    report = trainer.fit(
        LMStream(cfg, args.batch, args.seq), args.steps - trainer.step_num
    )
    print(f"final loss {report['final_loss']:.4f} "
          f"({report['wall_s']:.1f}s)")


if __name__ == "__main__":
    main()
