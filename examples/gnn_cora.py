"""Full-batch GAT training on a synthetic Cora-shaped graph, with triangle
analytics as extra structural node features — the paper's algorithm feeding
the GNN substrate it shares.  Two columns come from one engine pass:
BFS level (already a by-product of the cover-edge plan) and the per-vertex
triangle count (``TCOptions(per_vertex=True)``), log-compressed since
triangle participation is heavy-tailed.

    PYTHONPATH=src python examples/gnn_cora.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import TCOptions, TriangleEngine
from repro.configs.data import gnn_batch
from repro.configs.registry import arch_module
from repro.graph.csr import from_edges
from repro.launch import steps as steps_mod
from repro.train.optimizer import OptConfig, opt_init


def triangle_features(edges: np.ndarray, n: int) -> jnp.ndarray:
    """float32[n, 2] structural columns from ONE engine pass: BFS level
    (scaled) and log1p per-vertex triangle count.  Sanity-gates the
    attribution the way CI smoke expects: finite and non-negative."""
    rep = TriangleEngine().count(
        from_edges(edges, n), options=TCOptions(per_vertex=True)
    )
    pv = np.asarray(rep.per_vertex)
    assert pv.shape == (n,), pv.shape
    assert np.isfinite(pv).all() and (pv >= 0).all(), "per-vertex counts must be finite and non-negative"
    assert int(pv.sum()) == 3 * int(rep.triangles)
    levels = jnp.asarray(rep.levels, jnp.float32) / 10.0
    tri = jnp.log1p(jnp.asarray(pv, jnp.float32))
    print(f"graph triangles: {rep.triangles}  k={rep.k:.3f}  "
          f"max per-vertex: {int(pv.max()) if n else 0}")
    return jnp.stack([levels, tri], axis=1)


def main():
    cfg = dataclasses.replace(arch_module("gat-cora").SMOKE, d_in=10,
                              n_classes=3)
    batch = gnn_batch("gat-cora", cfg, n_nodes=300, n_edges_und=1200,
                      d_feat=8, seed=1)
    edges = np.stack([np.asarray(batch.src), np.asarray(batch.dst)], 1)
    feats = triangle_features(edges, 300)
    batch = dataclasses.replace(
        batch, node_feat=jnp.concatenate([batch.node_feat, feats], axis=1)
    )

    params = steps_mod.init_for("gat-cora", cfg, jax.random.key(0))
    opt_cfg = OptConfig(lr=5e-3, warmup=5, total_steps=100)
    opt = opt_init(opt_cfg, params)
    step = jax.jit(steps_mod.gnn_train_step("gat-cora", cfg, opt_cfg))
    for i in range(100):
        params, opt, metrics = step(params, opt, batch)
        if (i + 1) % 20 == 0:
            print(f"step {i+1}: loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
