"""Full-batch GAT training on a synthetic Cora-shaped graph, with triangle
counts as extra structural node features — the paper's algorithm feeding
the GNN substrate it shares.

    PYTHONPATH=src python examples/gnn_cora.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.api import TriangleEngine
from repro.configs.data import gnn_batch
from repro.configs.registry import arch_module
from repro.graph.csr import from_edges
from repro.launch import steps as steps_mod
from repro.train.optimizer import OptConfig, opt_init


def main():
    cfg = dataclasses.replace(arch_module("gat-cora").SMOKE, d_in=9,
                              n_classes=3)
    batch = gnn_batch("gat-cora", cfg, n_nodes=300, n_edges_und=1200,
                      d_feat=8, seed=1)
    # --- structural feature from the paper's algorithm: per-vertex level
    import numpy as np

    g = from_edges(
        np.stack([np.asarray(batch.src), np.asarray(batch.dst)], 1), 300
    )
    rep = TriangleEngine().count(g)
    levels = jnp.asarray(rep.levels, jnp.float32)[:, None] / 10.0
    batch = dataclasses.replace(
        batch, node_feat=jnp.concatenate([batch.node_feat, levels], axis=1)
    )
    print(f"graph triangles: {rep.triangles}  k={rep.k:.3f}")

    params = steps_mod.init_for("gat-cora", cfg, jax.random.key(0))
    opt_cfg = OptConfig(lr=5e-3, warmup=5, total_steps=100)
    opt = opt_init(opt_cfg, params)
    step = jax.jit(steps_mod.gnn_train_step("gat-cora", cfg, opt_cfg))
    for i in range(100):
        params, opt, metrics = step(params, opt, batch)
        if (i + 1) % 20 == 0:
            print(f"step {i+1}: loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
