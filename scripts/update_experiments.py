"""Regenerate the generated sections of EXPERIMENTS.md from results/*.json.

    PYTHONPATH=src:. python scripts/update_experiments.py
"""
from __future__ import annotations

import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))
sys.path.insert(0, str(ROOT / "src"))

from benchmarks.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, analyze, to_markdown  # noqa: E402


def terms(rec):
    coll = sum(v for k, v in rec["collective_bytes"].items() if k != "count")
    return (rec["hlo_flops"] / PEAK_FLOPS, rec["hlo_bytes"] / HBM_BW,
            coll / LINK_BW, rec["peak_bytes"] / 2 ** 30)


def perf_summary() -> str:
    base = json.loads((ROOT / "results/dryrun_pod_baseline.json").read_text())
    opt = json.loads((ROOT / "results/dryrun_pod_opt.json").read_text())
    rows = [
        "| cell | variant | compute s | memory s | collective s | peak GB "
        "| dominant-term gain |",
        "|---|---|---|---|---|---|---|",
    ]
    picks = [
        ("smollm-135m|prefill_32k", "memory"),
        ("qwen2-moe-a2.7b|train_4k", "collective"),
        ("cover-edge-tc|rmat_pod", "memory"),
        ("gemma3-4b|decode_32k", "collective"),
        ("gemma3-1b|long_500k", "collective"),
        ("phi3.5-moe-42b-a6.6b|train_4k", "collective"),
    ]
    for key, dom in picks:
        if key not in base or key not in opt:
            continue
        b, o = base[key], opt[key]
        if b.get("status") != "ok" or o.get("status") != "ok":
            continue
        tb = dict(zip(("c", "m", "x", "p"), terms(b)))
        to_ = dict(zip(("c", "m", "x", "p"), terms(o)))
        dom_k = {"memory": "m", "collective": "x"}[dom]
        gain = tb[dom_k] / max(to_[dom_k], 1e-12)
        rows.append(
            f"| {key} | baseline | {tb['c']:.2e} | {tb['m']:.2e} |"
            f" {tb['x']:.2e} | {tb['p']:.1f} | |")
        rows.append(
            f"| {key} | optimized | {to_['c']:.2e} | {to_['m']:.2e} |"
            f" {to_['x']:.2e} | {to_['p']:.1f} | **{gain:,.0f}x {dom}** |")
    return "\n".join(rows)


def main():
    exp = (ROOT / "EXPERIMENTS.md").read_text()

    baseline_md = to_markdown(analyze("pod", variant="_baseline"))
    exp = re.sub(
        r"<!-- ROOFLINE_BASELINE -->.*?(?=\n\nReading the baseline)",
        "<!-- ROOFLINE_BASELINE -->\n\n" + baseline_md,
        exp, flags=re.S,
    )

    blocks = []
    for mesh in ("pod", "multipod"):
        p = ROOT / f"results/dryrun_{mesh}_opt.json"
        if p.exists():
            blocks.append(f"### Optimized roofline — {mesh} mesh\n\n"
                          + to_markdown(analyze(mesh, variant="_opt")))
    if blocks:
        section = "<!-- PERF_SUMMARY -->\n\n" + perf_summary() + \
            "\n\n" + "\n\n".join(blocks) + "\n"
        exp = re.sub(r"<!-- PERF_SUMMARY -->.*", section, exp, flags=re.S)

    (ROOT / "EXPERIMENTS.md").write_text(exp)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
